//! Serving-engine benchmark: static windows vs iteration-level continuous
//! batching under a mixed-size Poisson offered load. Emits
//! `BENCH_serve.json` (in the crate directory) so the numbers are recorded
//! machine-readably (EXPERIMENTS.md §Serving): per offered-load point,
//! throughput, mean/p99 time-in-queue, shed rate, and mean tokens/batch
//! for both intake modes.

mod common;

use std::time::{Duration, Instant};

use sawtooth_attn::config::{PolicyConfig, QueueConfig, QueueMode, ServeConfig};
use sawtooth_attn::coordinator::{AttentionRequest, Engine, EngineStats};
use sawtooth_attn::runtime::default_artifacts_dir;
use sawtooth_attn::sim::shard::ShardConfig;
use sawtooth_attn::sim::traversal::TraversalRef;
use sawtooth_attn::util::rng::Rng;

const REQUESTS: usize = 240;
const CLIENTS: usize = 6;
const OFFERED_RPS: [f64; 3] = [100.0, 400.0, 1600.0];
/// Max handles a client holds before draining (bounds client memory, not
/// the engine).
const IN_FLIGHT: usize = 16;

struct RunPoint {
    throughput_rps: f64,
    tiq_mean_ms: f64,
    tiq_p99_ms: f64,
    shed_rate: f64,
    mean_tokens_per_batch: f64,
    mean_batch_size: f64,
}

fn serve_cfg(mode: QueueMode) -> ServeConfig {
    ServeConfig {
        artifacts_dir: default_artifacts_dir().display().to_string(),
        max_batch: 4,
        batch_window_us: 2000,
        order: TraversalRef::sawtooth(),
        queue_depth: 64,
        clients: CLIENTS,
        warmup: true,
        policy: PolicyConfig::default(),
        queue: QueueConfig {
            mode,
            max_waiting: 64,
            max_batch_total_tokens: 4 * 131_072, // four seq-512 requests
            ..QueueConfig::default()
        },
        shard: ShardConfig::default(),
    }
}

/// Drive one (mode, offered load) point: CLIENTS threads submit a mixed
/// 128/256/512 load with Poisson (exponential) interarrival gaps tuned so
/// the aggregate offered rate is `offered_rps`.
fn drive(mode: QueueMode, offered_rps: f64) -> Option<(f64, EngineStats)> {
    let engine = match Engine::start(serve_cfg(mode)) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping bench_coordinator: {e:#} (run `make artifacts`)");
            return None;
        }
    };
    let mean_gap_s = CLIENTS as f64 / offered_rps;
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..CLIENTS {
            let engine = &engine;
            s.spawn(move || {
                let mut rng = Rng::new(0xBEEF ^ c as u64);
                let seqs = [128usize, 256, 512];
                let mut handles = Vec::new();
                for i in 0..REQUESTS / CLIENTS {
                    // Exponential interarrival gap (capped so one long
                    // draw can't stall a client).
                    let u = (1.0 - rng.next_f64()).max(1e-12);
                    let gap = (-u.ln() * mean_gap_s).min(0.05);
                    std::thread::sleep(Duration::from_secs_f64(gap));
                    let seq = seqs[rng.next_below(3) as usize];
                    let req = AttentionRequest::synthetic(
                        (c * 10_000 + i) as u64,
                        seq,
                        4,
                        64,
                        false,
                        &mut rng,
                    );
                    // Rejections (back-pressure / shed) are part of the
                    // measurement: the request is simply lost.
                    if let Ok(h) = engine.submit_async(req) {
                        handles.push(h);
                    }
                    if handles.len() >= IN_FLIGHT {
                        for h in handles.drain(..) {
                            let _ = h.wait();
                        }
                    }
                }
                for h in handles {
                    let _ = h.wait();
                }
            });
        }
    });
    let elapsed = t0.elapsed();
    let stats = engine.shutdown();
    Some((elapsed.as_secs_f64(), stats))
}

fn point(mode: QueueMode, offered_rps: f64) -> Option<RunPoint> {
    let (elapsed_s, stats) = drive(mode, offered_rps)?;
    let offered = stats.submitted + stats.rejected;
    let shed_rate = if offered == 0 {
        0.0
    } else {
        stats.rejected as f64 / offered as f64
    };
    let p = RunPoint {
        throughput_rps: stats.completed as f64 / elapsed_s,
        tiq_mean_ms: stats.time_in_queue.mean(),
        tiq_p99_ms: stats.time_in_queue.p99(),
        shed_rate,
        mean_tokens_per_batch: stats.mean_tokens_per_batch(),
        mean_batch_size: stats.mean_batch_size(),
    };
    println!(
        "bench serve/{mode:<10} offered {offered_rps:>6.0} rps  →  {:.1} req/s, \
         in-queue mean {:.2} ms p99 {:.2} ms, shed {:.1}%, \
         tokens/batch {:.0}, mean batch {:.2}",
        p.throughput_rps,
        p.tiq_mean_ms,
        p.tiq_p99_ms,
        100.0 * p.shed_rate,
        p.mean_tokens_per_batch,
        p.mean_batch_size,
    );
    Some(p)
}

fn json_point(p: &RunPoint) -> String {
    format!(
        "{{\"throughput_rps\": {:.3}, \"tiq_mean_ms\": {:.4}, \"tiq_p99_ms\": {:.4}, \
         \"shed_rate\": {:.4}, \"mean_tokens_per_batch\": {:.1}, \"mean_batch_size\": {:.3}}}",
        p.throughput_rps,
        p.tiq_mean_ms,
        p.tiq_p99_ms,
        p.shed_rate,
        p.mean_tokens_per_batch,
        p.mean_batch_size,
    )
}

fn main() {
    println!(
        "== bench_coordinator: static windows vs continuous batching \
         ({REQUESTS} requests, {CLIENTS} clients, mixed 128/256/512 Poisson load) =="
    );
    let mut entries = Vec::new();
    for &rps in &OFFERED_RPS {
        let st = point(QueueMode::Static, rps);
        let co = point(QueueMode::Continuous, rps);
        let (Some(st), Some(co)) = (st, co) else {
            return; // skip reason already printed
        };
        println!(
            "      continuous vs static at {rps:.0} rps: tokens/batch {:.2}x, \
             in-queue p99 {:.2}x",
            co.mean_tokens_per_batch / st.mean_tokens_per_batch.max(1.0),
            co.tiq_p99_ms / st.tiq_p99_ms.max(1e-9),
        );
        entries.push(format!(
            "    {{\"offered_rps\": {rps:.0}, \"static\": {}, \"continuous\": {}}}",
            json_point(&st),
            json_point(&co)
        ));
    }
    let json = format!(
        "{{\n  \"bench\": \"serve\",\n  \"requests\": {REQUESTS},\n  \"clients\": {CLIENTS},\n  \
         \"points\": [\n{}\n  ]\n}}\n",
        entries.join(",\n")
    );
    let path = "BENCH_serve.json";
    match std::fs::write(path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
    print!("{json}");
}
