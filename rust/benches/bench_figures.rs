//! Regenerate every paper figure (1–12) and time each (`cargo bench`).
//!
//! This is the full evaluation harness: each figure's workload sweep runs
//! on the GB10 simulator and prints the same series the paper plots, with
//! paper reference values alongside.

mod common;

use common::bench_once;
use sawtooth_attn::report;

fn main() {
    println!("== bench_figures: paper figures 1-12 ==");
    for i in 1..=12 {
        let id = format!("fig{i}");
        let out = bench_once(&format!("report/{id}"), || report::run(&id).unwrap());
        println!("{out}");
    }
}
