//! Reuse-distance fast-path benchmark: an 8-capacity L2 ablation sweep
//! (× 2 traversal orders) executed as (a) one LRU simulation per capacity
//! — the pre-fast-path baseline, `--no-mattson` — versus (b) one Mattson
//! profile pass per order fanned out to every capacity. Emits
//! `BENCH_reuse.json` (in the crate directory) with the raw timings so the
//! grouped-vs-ungrouped speedup is recorded machine-readably
//! (EXPERIMENTS.md §Reuse).

use std::time::Instant;

use sawtooth_attn::sim::sweep::{SweepExecutor, SweepGrid};
use sawtooth_attn::sim::traversal::TraversalRef;
use sawtooth_attn::sim::workload::AttentionWorkload;
use sawtooth_attn::sim::SimConfig;

const CAPACITY_MIBS: [u64; 8] = [4, 6, 8, 10, 12, 16, 20, 24];

fn grid() -> Vec<SimConfig> {
    // The §3 CUDA study at S=64K (KV = 16 MiB per direction pair, 32 MiB
    // total): every capacity below sits in the interesting regime, heavy
    // enough that per-access work dominates, small enough for CI. 8
    // capacities × 2 orders = 16 configs = 2 profile passes on the fast
    // path vs 16 simulations without it.
    let caps: Vec<u64> = CAPACITY_MIBS.iter().map(|m| m << 20).collect();
    let base = SimConfig::cuda_study(AttentionWorkload::cuda_study(64 * 1024));
    SweepGrid::new(base)
        .orders(&[TraversalRef::cyclic(), TraversalRef::sawtooth()])
        .l2_bytes(&caps)
        .build("bench-reuse")
        .configs
}

fn main() {
    println!("== bench_reuse: grouped (Mattson) vs ungrouped capacity sweep ==");
    let configs = grid();

    // Single-threaded on both sides: this measures the algorithmic win of
    // one-pass profiling, not thread-pool fan-out (bench_sweep covers that).
    let t0 = Instant::now();
    let exact = SweepExecutor::new(1).with_mattson(false);
    let baseline = exact.run_all(&configs);
    let ungrouped_s = t0.elapsed().as_secs_f64();
    println!(
        "bench reuse/ungrouped ({} sims)                    {ungrouped_s:>10.3}s",
        configs.len()
    );

    let t0 = Instant::now();
    let fast = SweepExecutor::new(1);
    let grouped = fast.run_all(&configs);
    let grouped_s = t0.elapsed().as_secs_f64();
    let speedup = ungrouped_s / grouped_s;
    println!(
        "bench reuse/grouped ({} profile passes)             {grouped_s:>10.3}s  (speedup {speedup:.2}x)",
        fast.profiled_len()
    );

    let identical = baseline
        .iter()
        .zip(&grouped)
        .all(|(a, b)| **a == **b);
    println!("results bit-identical across paths: {identical}");
    assert!(identical, "fast path diverged from per-capacity simulation");

    // Curve re-query cost: answering 64 *new* capacities from the cached
    // curves (the policy probe's what-if path) — no further trace passes.
    let t0 = Instant::now();
    let mut extra = 0u64;
    for i in 0..64u64 {
        let mut cfg = configs[0].clone();
        cfg.device.l2_bytes = (25 + i) << 20;
        extra += fast.run_at_capacity(&cfg).counters.l2_miss_sectors;
    }
    let requery_s = t0.elapsed().as_secs_f64();
    println!(
        "bench reuse/64 what-if capacities from cached curve {requery_s:>10.6}s  (checksum {extra})"
    );

    let json = format!(
        "{{\n  \"bench\": \"reuse_fast_path\",\n  \"grid\": \"cuda_study S=64K x order(cyclic,sawtooth) x l2({} caps)\",\n  \"configs\": {},\n  \"capacities\": {},\n  \"ungrouped_s\": {:.6},\n  \"grouped_s\": {:.6},\n  \"speedup\": {:.3},\n  \"results_identical\": {},\n  \"whatif_64caps_s\": {:.6}\n}}\n",
        CAPACITY_MIBS.len(),
        configs.len(),
        CAPACITY_MIBS.len(),
        ungrouped_s,
        grouped_s,
        speedup,
        identical,
        requery_s
    );
    let path = "BENCH_reuse.json";
    match std::fs::write(path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
    print!("{json}");
}
