//! Reuse-distance fast-path benchmark: an 8-capacity L2 ablation sweep
//! (× 2 traversal orders) executed as (a) one LRU simulation per capacity
//! — the pre-fast-path baseline, `--no-mattson` — versus (b) one Mattson
//! profile pass per order fanned out to every capacity. A second headline
//! measures the front-stack fast path itself on the §4.3 CuTile study
//! shape (S=128K, B=8): one Mattson profile with the front stack enabled
//! (the default) versus disabled, curves asserted bit-identical, plus the
//! fast-path engagement ratio on both paper study shapes. Emits
//! `BENCH_reuse.json` (in the crate directory) with the raw timings so the
//! grouped-vs-ungrouped speedup is recorded machine-readably
//! (EXPERIMENTS.md §Reuse). CI's perf-smoke gate checks the engagement
//! fields (counter-based, so not flaky); the timings are informational.

use std::time::Instant;

use sawtooth_attn::sim::kernel_model::KernelVariant;
use sawtooth_attn::sim::sweep::{SweepExecutor, SweepGrid};
use sawtooth_attn::sim::traversal::TraversalRef;
use sawtooth_attn::sim::workload::AttentionWorkload;
use sawtooth_attn::sim::{SimConfig, Simulator};

const CAPACITY_MIBS: [u64; 8] = [4, 6, 8, 10, 12, 16, 20, 24];

fn grid() -> Vec<SimConfig> {
    // The §3 CUDA study at S=64K (KV = 16 MiB per direction pair, 32 MiB
    // total): every capacity below sits in the interesting regime, heavy
    // enough that per-access work dominates, small enough for CI. 8
    // capacities × 2 orders = 16 configs = 2 profile passes on the fast
    // path vs 16 simulations without it.
    let caps: Vec<u64> = CAPACITY_MIBS.iter().map(|m| m << 20).collect();
    let base = SimConfig::cuda_study(AttentionWorkload::cuda_study(64 * 1024));
    SweepGrid::new(base)
        .orders(&[TraversalRef::cyclic(), TraversalRef::sawtooth()])
        .l2_bytes(&caps)
        .build("bench-reuse")
        .configs
}

fn main() {
    println!("== bench_reuse: grouped (Mattson) vs ungrouped capacity sweep ==");
    let configs = grid();

    // Single-threaded on both sides: this measures the algorithmic win of
    // one-pass profiling, not thread-pool fan-out (bench_sweep covers that).
    let t0 = Instant::now();
    let exact = SweepExecutor::new(1).with_mattson(false);
    let baseline = exact.run_all(&configs);
    let ungrouped_s = t0.elapsed().as_secs_f64();
    println!(
        "bench reuse/ungrouped ({} sims)                    {ungrouped_s:>10.3}s",
        configs.len()
    );

    let t0 = Instant::now();
    let fast = SweepExecutor::new(1);
    let grouped = fast.run_all(&configs);
    let grouped_s = t0.elapsed().as_secs_f64();
    let speedup = ungrouped_s / grouped_s;
    println!(
        "bench reuse/grouped ({} profile passes)             {grouped_s:>10.3}s  (speedup {speedup:.2}x)",
        fast.profiled_len()
    );

    let identical = baseline
        .iter()
        .zip(&grouped)
        .all(|(a, b)| **a == **b);
    println!("results bit-identical across paths: {identical}");
    assert!(identical, "fast path diverged from per-capacity simulation");

    // Curve re-query cost: answering 64 *new* capacities from the cached
    // curves (the policy probe's what-if path) — no further trace passes.
    let t0 = Instant::now();
    let mut extra = 0u64;
    for i in 0..64u64 {
        let mut cfg = configs[0].clone();
        cfg.device.l2_bytes = (25 + i) << 20;
        extra += fast.run_at_capacity(&cfg).counters.l2_miss_sectors;
    }
    let requery_s = t0.elapsed().as_secs_f64();
    println!(
        "bench reuse/64 what-if capacities from cached curve {requery_s:>10.6}s  (checksum {extra})"
    );

    // Engagement on the S=64K CUDA study: the grouped run above executed
    // exactly two Mattson profile passes; their merged front-stack counters
    // live in the executor's timing aggregate.
    let cuda_engagement = fast.timing().fastpath.engagement();
    println!("bench reuse/cuda engagement (front-stack hit ratio)   {cuda_engagement:>9.4}");

    // Headline: the §4.3 CuTile study shape (S=128K, B=8, T=64; ~67M KV
    // accesses) profiled once with the front-stack fast path (the default)
    // and once without. Same trace, same curve — only the per-access cost
    // differs (O(1) ring touch vs O(log n) Fenwick update).
    let cutile = SimConfig::cutile_study(
        AttentionWorkload::cutile_study(8, false),
        KernelVariant::CuTileTile,
        TraversalRef::sawtooth(),
    );
    let t0 = Instant::now();
    let fast_profile = Simulator::new(cutile.clone()).profile();
    let cutile_fast_s = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let slow_profile = Simulator::new(cutile.clone()).with_fast_path(false).profile();
    let cutile_slow_s = t0.elapsed().as_secs_f64();
    let cutile_speedup = cutile_slow_s / cutile_fast_s;
    println!("bench reuse/cutile S=128K profile, front stack on   {cutile_fast_s:>10.3}s");
    println!(
        "bench reuse/cutile S=128K profile, front stack off  {cutile_slow_s:>10.3}s  (speedup {cutile_speedup:.2}x)"
    );

    // Bit-identity of the two curves, checked where they are consumed:
    // derived SimResults at every benchmark capacity plus GB10's 24 MiB.
    let cutile_curves_identical = CAPACITY_MIBS.iter().all(|&mib| {
        let mut probe = cutile.clone();
        probe.device.l2_bytes = mib << 20;
        let cap = probe.device.l2_sectors();
        fast_profile.result_at(cap) == slow_profile.result_at(cap)
    });
    println!("cutile curves bit-identical across paths: {cutile_curves_identical}");
    assert!(cutile_curves_identical, "front stack diverged from the Fenwick-only path");

    let cutile_engagement = fast_profile.front_stats().engagement();
    println!("bench reuse/cutile engagement (front-stack hit ratio) {cutile_engagement:>9.4}");
    // Counter-based acceptance (not timing-based, so not flaky): the paper
    // study shapes must resolve >= 90% of warm accesses inside the front
    // stack — the whole premise of the fast path.
    assert!(
        cuda_engagement >= 0.9,
        "cuda S=64K engagement {cuda_engagement:.4} below the 90% gate"
    );
    assert!(
        cutile_engagement >= 0.9,
        "cutile S=128K engagement {cutile_engagement:.4} below the 90% gate"
    );

    let json = format!(
        "{{\n  \"bench\": \"reuse_fast_path\",\n  \"grid\": \"cuda_study S=64K x order(cyclic,sawtooth) x l2({} caps)\",\n  \"configs\": {},\n  \"capacities\": {},\n  \"ungrouped_s\": {:.6},\n  \"grouped_s\": {:.6},\n  \"speedup\": {:.3},\n  \"results_identical\": {},\n  \"whatif_64caps_s\": {:.6},\n  \"cuda_engagement\": {:.6},\n  \"cutile_grid\": \"cutile_study S=128K B=8 T=64 sawtooth, Mattson profile\",\n  \"cutile_fast_s\": {:.6},\n  \"cutile_slow_s\": {:.6},\n  \"cutile_speedup\": {:.3},\n  \"cutile_engagement\": {:.6},\n  \"cutile_curves_identical\": {}\n}}\n",
        CAPACITY_MIBS.len(),
        configs.len(),
        CAPACITY_MIBS.len(),
        ungrouped_s,
        grouped_s,
        speedup,
        identical,
        requery_s,
        cuda_engagement,
        cutile_fast_s,
        cutile_slow_s,
        cutile_speedup,
        cutile_engagement,
        cutile_curves_identical
    );
    let path = "BENCH_reuse.json";
    match std::fs::write(path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
    print!("{json}");
}
