# Convenience targets. The Rust workspace builds hermetically (vendored
# deps); the artifacts target needs a Python environment with JAX.

.PHONY: build test bench bench-perf artifacts report clean

build:
	cd rust && cargo build --release

# Tier-1 verification.
test:
	cd rust && cargo build --release && cargo test -q

bench:
	cd rust && cargo bench

# Run the perf benches and fold their measured numbers into
# EXPERIMENTS.md (between the BENCH markers).
bench-perf:
	cd rust && cargo bench --bench bench_sweep && cargo bench --bench bench_reuse \
		&& cargo bench --bench bench_policy && cargo bench --bench bench_coordinator \
		&& cargo bench --bench bench_decode && cargo bench --bench bench_hierarchy \
		&& cargo bench --bench bench_shard
	python3 scripts/update_experiments_perf.py

# Lower the Pallas/JAX attention variants to HLO text + manifest.tsv.
# Without this, the Rust runtime serves from a synthetic manifest via the
# host reference executor (see rust/src/runtime/mod.rs).
artifacts:
	cd python && PYTHONPATH=. python3 -m compile.aot --out-dir ../rust/artifacts

report:
	cd rust && cargo run --release --bin sawtooth -- report all

clean:
	cd rust && cargo clean
