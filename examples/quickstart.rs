//! Quickstart: load an AOT attention artifact, execute it through the Rust
//! runtime, and check the numerics against a host reference. Runs
//! hermetically (synthetic manifest + host executor) when no artifacts
//! directory exists.
//!
//! Run with: `cargo run --release --example quickstart`
//! (optionally `make artifacts` first to serve from real AOT metadata)

use anyhow::Result;

use sawtooth_attn::runtime::{attention_host_ref, default_artifacts_dir, Runtime};
use sawtooth_attn::util::rng::Rng;

fn main() -> Result<()> {
    let dir = default_artifacts_dir();
    println!("opening artifacts at {}", dir.display());
    let mut rt = Runtime::open(&dir)?;
    println!("runtime platform: {}", rt.platform_name());

    // Pick the smallest sawtooth variant: the paper's optimization, as the
    // serving engine would select it.
    let meta = rt
        .manifest()
        .attention_artifacts()
        .filter(|a| a.order == "sawtooth" && !a.causal && a.batch == 1)
        .min_by_key(|a| a.seq)
        .expect("run `make artifacts` first")
        .clone();
    println!(
        "artifact: {} (B={} H={} S={} D={}, tile {}x{}, order={})",
        meta.name, meta.batch, meta.heads, meta.seq, meta.head_dim, meta.tile_q, meta.tile_kv,
        meta.order
    );

    // Synthetic inputs.
    let n = meta.qkv_elems();
    let mut rng = Rng::new(42);
    let mut gen = || -> Vec<f32> { (0..n).map(|_| rng.next_gaussian() as f32 * 0.5).collect() };
    let (q, k, v) = (gen(), gen(), gen());

    // Execute the artifact through the runtime's host executor.
    let t0 = std::time::Instant::now();
    let out = rt.execute_attention(&meta.name, &q, &k, &v)?;
    println!("executed in {:?} ({} output elements)", t0.elapsed(), out.len());

    // Validate against the host oracle. Note: in hermetic mode the runtime
    // *executes* with the host oracle, so this only exercises the routing /
    // batching plumbing, not independent numerics — say so rather than
    // claiming a vacuous check.
    let reference = attention_host_ref(
        &q, &k, &v, meta.batch, meta.heads, meta.seq, meta.head_dim, meta.causal,
    );
    let max_err = out
        .iter()
        .zip(&reference)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    if rt.is_synthetic() {
        println!(
            "max |runtime - host_ref| = {max_err:.2e} (hermetic mode: runtime *is* the \
             host oracle — this checks plumbing, not independent numerics)"
        );
    } else {
        println!("max |runtime - host_ref| = {max_err:.2e}");
    }
    assert!(max_err < 1e-4, "numerics mismatch: {max_err}");

    // And the sawtooth artifact must agree with the cyclic one.
    let cyclic = meta.name.replace("sawtooth", "cyclic");
    let out_cyc = rt.execute_attention(&cyclic, &q, &k, &v)?;
    let max_diff = out
        .iter()
        .zip(&out_cyc)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!("max |sawtooth - cyclic| = {max_diff:.2e} (pure fp reassociation)");
    assert!(max_diff < 1e-4);

    if rt.is_synthetic() {
        println!("quickstart OK — manifest → runtime plumbing verified (hermetic mode)");
    } else {
        println!("quickstart OK — artifact manifest → runtime → numerics verified");
    }
    Ok(())
}
