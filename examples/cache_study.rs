//! Cache-study example: reproduce the paper's §3 analysis pipeline end to
//! end — model validation (Figs 3–4), the capacity threshold (Fig 5), the
//! wavefront-reuse law (Fig 6), and two ablations beyond the paper
//! (jitter desynchronization; L2 capacity sweep).
//!
//! Run with: `cargo run --release --example cache_study`

use sawtooth_attn::gb10::DeviceSpec;
use sawtooth_attn::l2model;
use sawtooth_attn::sim::engine::cold_sectors;
use sawtooth_attn::sim::workload::AttentionWorkload;
use sawtooth_attn::sim::{SimConfig, Simulator, TraversalRef};

fn main() {
    println!("== 1. L2 sector model validation (paper §3.2, Figs 3-4) ==");
    println!("{:<8} {:>16} {:>16} {:>8}", "S", "simulated", "model", "err %");
    for causal in [false, true] {
        println!("-- {} --", if causal { "causal" } else { "non-causal" });
        for sk in [16u64, 48, 96, 128] {
            let w = AttentionWorkload::cuda_study(sk * 1024).with_causal(causal);
            let r = Simulator::new(SimConfig::cuda_study(w)).run();
            let m = l2model::sectors_model(&w, 32);
            let sim = r.counters.l2_sectors_from_tex as f64;
            println!(
                "{:<8} {:>16.0} {:>16.0} {:>8.3}",
                format!("{}K", sk),
                sim,
                m,
                100.0 * (sim - m).abs() / m
            );
        }
    }

    println!("\n== 2. Non-compulsory miss threshold (paper §3.3, Fig 5) ==");
    let dev = DeviceSpec::gb10();
    println!(
        "idealised threshold: KV = L2 at S = {}K",
        l2model::capacity_threshold_seq(&AttentionWorkload::cuda_study(1), dev.l2_bytes) / 1024
    );
    for sk in [64u64, 80, 88, 96, 112] {
        let w = AttentionWorkload::cuda_study(sk * 1024);
        let r = Simulator::new(SimConfig::cuda_study(w)).run();
        let cold = cold_sectors(&w, &dev);
        println!(
            "S={:>4}K  KV={:>5.1} MiB  misses={:>11}  cold={:>9}  non-compulsory={:>11}",
            sk,
            w.kv_bytes() as f64 / (1 << 20) as f64,
            r.counters.l2_miss_sectors,
            cold,
            r.non_compulsory_misses(&w, &dev)
        );
    }

    println!("\n== 3. Wavefront reuse: hit rate ≈ 1 - 1/N_SM (paper §3.4, Fig 6) ==");
    for sms in [2u32, 8, 24, 48] {
        let w = AttentionWorkload::cuda_study(128 * 1024);
        let r = Simulator::new(SimConfig::cuda_study(w).with_sms(sms)).run();
        println!(
            "SM={:>2}  hit rate {:>6.2}%  model {:>6.2}%",
            sms,
            r.counters.l2_hit_rate_pct(),
            100.0 * l2model::wavefront_hit_rate(sms)
        );
    }

    println!("\n== 4. Ablation: jitter desynchronizes the wavefront ==");
    println!("(the 1 - 1/N law requires synchronized CTA progress; jitter breaks it)");
    let w = AttentionWorkload::cuda_study(96 * 1024);
    for jitter in [0.0, 0.1, 0.3, 0.6] {
        let cfg = SimConfig::cuda_study(w).with_jitter(jitter, 1234);
        let r = Simulator::new(cfg).run();
        println!(
            "jitter={:.1}  hit rate {:>6.2}%  misses {:>11}",
            jitter,
            r.counters.l2_hit_rate_pct(),
            r.counters.l2_miss_sectors
        );
    }

    println!("\n== 5. Ablation: L2 capacity sweep (threshold tracks KV ≈ C) ==");
    let w = AttentionWorkload::cuda_study(64 * 1024); // KV = 16 MiB
    for l2_mib in [8u64, 12, 16, 20, 24] {
        let mut cfg = SimConfig::cuda_study(w);
        cfg.device = DeviceSpec::gb10_with_l2(l2_mib << 20);
        let cyc = Simulator::new(cfg.clone()).run();
        let saw = Simulator::new(cfg.with_order(TraversalRef::sawtooth())).run();
        println!(
            "L2={:>2} MiB  cyclic misses {:>11}  sawtooth misses {:>11}  ({})",
            l2_mib,
            cyc.counters.l2_miss_sectors,
            saw.counters.l2_miss_sectors,
            if (l2_mib << 20) > w.kv_bytes() { "KV fits" } else { "KV ≥ L2" }
        );
    }
}
