//! Sawtooth Wavefront Reordering demo: the paper's core result in one run.
//!
//! Simulates the CuTile study configuration (T=64, B=8, S=128K, D=64) on
//! the GB10 device model under both traversal orders, printing the miss
//! reduction and throughput gain (paper Figs 9–12), plus the reuse-distance
//! explanation (§4).
//!
//! Run with: `cargo run --release --example sawtooth_demo`

use sawtooth_attn::gb10::DeviceSpec;
use sawtooth_attn::l2model::reuse::ReuseProfiler;
use sawtooth_attn::sim::cache::block_key;
use sawtooth_attn::sim::kernel_model::{
    kv_tile_at, kv_tiles_for, Direction, KernelVariant, WorkItem,
};
use sawtooth_attn::sim::throughput::{estimate, PerfProfile};
use sawtooth_attn::sim::traversal::TraversalRef;
use sawtooth_attn::sim::workload::AttentionWorkload;
use sawtooth_attn::sim::{SimConfig, Simulator};

fn main() {
    let dev = DeviceSpec::gb10();
    println!(
        "device: {} — {} SMs, {} MiB L2, {:.0} GB/s DRAM",
        dev.name,
        dev.num_sms,
        dev.l2_bytes >> 20,
        dev.dram_bw / 1e9
    );

    for causal in [false, true] {
        let w = AttentionWorkload::cutile_study(8, causal);
        println!(
            "\n== CuTile study: B=8, S=128K, D=64, T=64, {} ==",
            if causal { "causal" } else { "non-causal" }
        );
        println!(
            "KV working set: {} MiB per (batch,head) vs {} MiB L2",
            w.kv_bytes() >> 20,
            dev.l2_bytes >> 20
        );
        let mut cyc_time = 0.0;
        let mut saw_time = 0.0;
        for order in [TraversalRef::cyclic(), TraversalRef::sawtooth()] {
            let cfg = SimConfig::cutile_study(w, KernelVariant::CuTileStatic, order.clone());
            let t0 = std::time::Instant::now();
            let r = Simulator::new(cfg).run();
            let perf = estimate(&w, &dev, &r.counters, &PerfProfile::cutile());
            println!(
                "{:<9} L2 misses {:>13}  hit rate {:>6.2}%  est. {:>5.1} TFLOPS  (sim {:?})",
                order.name(),
                r.counters.l2_miss_sectors,
                r.counters.l2_hit_rate_pct(),
                perf.tflops,
                t0.elapsed()
            );
            if order == TraversalRef::cyclic() {
                cyc_time = perf.time_s;
            } else {
                saw_time = perf.time_s;
            }
        }
        println!("sawtooth speedup: {:.2}x", cyc_time / saw_time);
    }

    // Why it works: reuse distances of a single CTA's KV stream.
    println!("\n== Reuse-distance view (paper §4) ==");
    let w = AttentionWorkload::cuda_study(128 * 1024);
    for order in [TraversalRef::cyclic(), TraversalRef::sawtooth()] {
        let n = w.num_tiles();
        let mut prof = ReuseProfiler::new((2 * n * n + 2 * n) as usize);
        for q in 0..n {
            let dir = if order == TraversalRef::sawtooth() && q % 2 == 1 {
                Direction::Backward
            } else {
                Direction::Forward
            };
            let item = WorkItem { batch_head: 0, q_tile: q, direction: dir };
            for pos in 0..kv_tiles_for(&w, q) {
                let j = kv_tile_at(&w, &item, pos);
                let sec = w.rows_sectors(w.tile_rows(j), 32);
                prof.access(block_key(1, 0, j), sec);
                prof.access(block_key(2, 0, j), sec);
            }
        }
        let p = prof.finish();
        let l2 = DeviceSpec::gb10().l2_sectors();
        println!(
            "{:<9} mean reuse distance {:>9.0} sectors; predicted misses at 24 MiB: {:>9}",
            order.name(),
            p.mean_finite_distance(),
            p.misses_at(l2)
        );
    }
    println!(
        "\ncyclic: every reuse distance equals the KV size (misses whenever KV > L2);\n\
         sawtooth: each direction reversal re-touches the cached tail first,\n\
         pulling most reuse distances below the cache size."
    );
}
