//! End-to-end serving driver (the repo's headline example).
//!
//! Proves all layers compose: Pallas flash-attention kernels (L1) lowered
//! through JAX (L2) to HLO artifacts, executed by the PJRT runtime inside
//! the Rust serving coordinator (L3) under a concurrent synthetic load —
//! with iteration-level continuous batching (token-budget admission,
//! `waiting_served_ratio` dispatch), back-pressure, and the sawtooth
//! scheduling policy. Reports latency/throughput and validates numerics
//! on the fly.
//!
//! Also loads the small *real model* artifact (an MHA block with trained-
//! style projection weights) and serves one forward pass through it.
//!
//! Run with: `make artifacts && cargo run --release --example serve_attention`

use std::time::Instant;

use anyhow::Result;

use sawtooth_attn::config::{PolicyConfig, QueueConfig, QueueMode, ServeConfig};
use sawtooth_attn::coordinator::{AttentionRequest, Engine};
use sawtooth_attn::runtime::{attention_host_ref, default_artifacts_dir, Runtime};
use sawtooth_attn::sim::traversal::TraversalRef;
use sawtooth_attn::util::rng::Rng;

const TOTAL_REQUESTS: usize = 96;
const CLIENTS: usize = 6;

fn main() -> Result<()> {
    let artifacts = default_artifacts_dir();

    // ---- Phase 1: serve a concurrent attention load through the engine.
    let cfg = ServeConfig {
        artifacts_dir: artifacts.display().to_string(),
        max_batch: 4,
        batch_window_us: 2000,
        order: TraversalRef::sawtooth(),
        queue_depth: 64,
        clients: CLIENTS,
        warmup: true,
        policy: PolicyConfig::default(),
        // The headline intake: iteration-level continuous batching with a
        // bounded waiting queue and a per-dispatch token budget.
        queue: QueueConfig {
            mode: QueueMode::Continuous,
            max_waiting: 64,
            ..QueueConfig::default()
        },
    };
    println!(
        "engine: order={} max_batch={} window={}µs queue mode={} (max_waiting={}, \
         token budget={})",
        cfg.order,
        cfg.max_batch,
        cfg.batch_window_us,
        cfg.queue.mode,
        cfg.queue.max_waiting,
        cfg.queue.max_batch_total_tokens,
    );
    let engine = Engine::start(cfg)?;

    let t0 = Instant::now();
    let verified = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|s| {
        for c in 0..CLIENTS {
            let engine = &engine;
            let verified = &verified;
            s.spawn(move || {
                let mut rng = Rng::new(0xFEED + c as u64);
                let seqs = [128usize, 256, 512];
                let per_client = TOTAL_REQUESTS / CLIENTS;
                // Submit asynchronously in bounded waves (max 4 in flight
                // per client) so the batcher sees concurrent same-shape
                // requests without flooding the bounded queue.
                const IN_FLIGHT: usize = 4;
                let settle = |batch: Vec<(
                    AttentionRequest,
                    sawtooth_attn::coordinator::ResponseHandle,
                )>| {
                    for (req, h) in batch {
                        let resp = h.wait().expect("request failed");
                        assert_eq!(resp.output.len(), req.elems());
                        // Spot-check numerics on a sample of responses.
                        if req.id.0 % 17 == 0 {
                            let reference = attention_host_ref(
                                &req.q, &req.k, &req.v, 1, req.heads, req.seq,
                                req.head_dim, req.causal,
                            );
                            let max_err = resp
                                .output
                                .iter()
                                .zip(&reference)
                                .map(|(a, b)| (a - b).abs())
                                .fold(0f32, f32::max);
                            assert!(max_err < 1e-3, "req {} err {max_err}", req.id.0);
                            verified.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        }
                    }
                };
                let mut pending = Vec::new();
                for i in 0..per_client {
                    let seq = seqs[i % seqs.len()];
                    let causal = (i / 3) % 2 == 0;
                    let req = AttentionRequest::synthetic(
                        (c * 1000 + i) as u64,
                        seq,
                        4,
                        64,
                        causal,
                        &mut rng,
                    );
                    loop {
                        match engine.submit_async(req.clone()) {
                            Ok(h) => {
                                pending.push((req, h));
                                break;
                            }
                            Err(_) => {
                                // Back-pressure: drain what we have, retry.
                                settle(std::mem::take(&mut pending));
                                std::thread::sleep(std::time::Duration::from_millis(2));
                            }
                        }
                    }
                    if pending.len() >= IN_FLIGHT {
                        settle(std::mem::take(&mut pending));
                    }
                }
                settle(pending);
            });
        }
    });
    let elapsed = t0.elapsed();
    let stats = engine.shutdown();
    println!("{}", stats.summary());
    println!("batch size histogram (size: dispatches):");
    for (size, n) in stats.batch_size_buckets() {
        if n > 0 {
            println!("  {size:>2}: {n}");
        }
    }
    println!(
        "served {} requests in {:.2?} → {:.1} req/s; {} responses numerically verified",
        stats.completed,
        elapsed,
        stats.completed as f64 / elapsed.as_secs_f64(),
        verified.load(std::sync::atomic::Ordering::Relaxed)
    );
    assert_eq!(stats.completed as usize, TOTAL_REQUESTS);
    assert!(stats.mean_batch_size() > 1.0, "batcher never coalesced requests");

    // ---- Phase 2: the small real model (MHA block) end to end.
    println!("\n== MHA model forward (AOT weights + Pallas kernel, causal sawtooth) ==");
    let mut rt = Runtime::open(&artifacts)?;
    let meta = rt
        .manifest()
        .mha_artifacts()
        .next()
        .expect("mha artifact missing — run `make artifacts`")
        .clone();
    let dm = meta.model_dim();
    let weights = rt.load_mha_weights(dm)?;
    let mut rng = Rng::new(7);
    let x: Vec<f32> = (0..meta.batch * meta.seq * dm)
        .map(|_| rng.next_gaussian() as f32 * 0.1)
        .collect();
    let x_shape = meta.x_shape();
    let w_shape = [dm as i64, dm as i64];
    let t0 = Instant::now();
    let y = rt.execute(
        &meta.name,
        &[
            (&x, &x_shape),
            (&weights[0], &w_shape),
            (&weights[1], &w_shape),
            (&weights[2], &w_shape),
            (&weights[3], &w_shape),
        ],
    )?;
    println!(
        "model {} ({} params) forward in {:?}; output norm {:.4}",
        meta.name,
        4 * dm * dm,
        t0.elapsed(),
        (y.iter().map(|v| (v * v) as f64).sum::<f64>() / y.len() as f64).sqrt()
    );
    assert_eq!(y.len(), x.len());
    assert!(y.iter().all(|v| v.is_finite()));
    println!("serve_attention OK — full three-layer serving stack verified");
    Ok(())
}
