"""Layer-2: JAX model definitions built on the Pallas flash-attention kernel.

Two levels of computation are exported to HLO:

* ``attention_forward`` — the bare batched attention op (B, H, S, D).  These
  artifacts back the coordinator's attention service and the quickstart.
* ``mha_block_forward`` — a full multi-head-attention block (QKV projection,
  flash attention, output projection, residual).  This is the "small real
  model" the end-to-end serving example drives.

Everything here is build-time Python: ``aot.py`` lowers these functions once
to HLO text and the rust runtime executes the artifacts via PJRT.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Literal

import jax
import jax.numpy as jnp

from compile.kernels.flash_attention import flash_attention_batched

Order = Literal["cyclic", "sawtooth"]


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    """Static configuration of one AOT attention variant."""

    batch: int
    heads: int
    seq: int
    head_dim: int
    tile_q: int = 64
    tile_kv: int = 64
    causal: bool = False
    order: Order = "cyclic"
    dtype: str = "float32"

    @property
    def name(self) -> str:
        mask = "causal" if self.causal else "full"
        return (
            f"attn_b{self.batch}_h{self.heads}_s{self.seq}_d{self.head_dim}"
            f"_{mask}_{self.order}"
        )

    @property
    def model_dim(self) -> int:
        return self.heads * self.head_dim

    def jnp_dtype(self):
        return jnp.dtype(self.dtype)


def attention_forward(cfg: AttentionConfig, q, k, v):
    """Batched flash attention, inputs ``(B, H, S, D)``."""
    return flash_attention_batched(
        q,
        k,
        v,
        tile_q=cfg.tile_q,
        tile_kv=cfg.tile_kv,
        causal=cfg.causal,
        order=cfg.order,
    )


def mha_block_forward(cfg: AttentionConfig, x, wq, wk, wv, wo):
    """A full MHA block over ``x: (B, S, H*D)``.

    y = x + (flash_attention(x Wq, x Wk, x Wv) reshaped) Wo

    Weights are ``(H*D, H*D)``.  The attention core is the Pallas kernel, so
    the sawtooth reorder is exercised inside a realistic model graph (the
    serving example's workload).
    """
    b, s, dm = x.shape
    h, dh = cfg.heads, cfg.head_dim
    assert dm == h * dh, (dm, h, dh)

    def split(t):
        # (B, S, H*D) -> (B, H, S, D)
        return t.reshape(b, s, h, dh).transpose(0, 2, 1, 3)

    q = split(x @ wq)
    k = split(x @ wk)
    v = split(x @ wv)
    o = attention_forward(cfg, q, k, v)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, dm)
    return x + o @ wo


def attention_example_args(cfg: AttentionConfig):
    """ShapeDtypeStructs for lowering ``attention_forward``."""
    shp = (cfg.batch, cfg.heads, cfg.seq, cfg.head_dim)
    spec = jax.ShapeDtypeStruct(shp, cfg.jnp_dtype())
    return (spec, spec, spec)


def mha_example_args(cfg: AttentionConfig):
    """ShapeDtypeStructs for lowering ``mha_block_forward``."""
    dm = cfg.model_dim
    x = jax.ShapeDtypeStruct((cfg.batch, cfg.seq, dm), cfg.jnp_dtype())
    w = jax.ShapeDtypeStruct((dm, dm), cfg.jnp_dtype())
    return (x, w, w, w, w)


def jit_attention(cfg: AttentionConfig):
    """Jitted single-output-tuple attention fn ready for lowering."""

    def fn(q, k, v):
        return (attention_forward(cfg, q, k, v),)

    return jax.jit(fn)


def jit_mha(cfg: AttentionConfig):
    def fn(x, wq, wk, wv, wo):
        return (mha_block_forward(cfg, x, wq, wk, wv, wo),)

    return jax.jit(fn)


def init_mha_weights(cfg: AttentionConfig, seed: int = 0):
    """Deterministic small random weights for the serving model."""
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    dm = cfg.model_dim
    scale = 1.0 / jnp.sqrt(dm)
    return tuple(
        (jax.random.normal(k, (dm, dm), cfg.jnp_dtype()) * scale) for k in keys
    )
