"""AOT pipeline: lower the JAX/Pallas model to HLO text artifacts.

Interchange format is HLO **text**, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).  The text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example/README.md).

Outputs, under ``artifacts/``:

  <name>.hlo.txt      one per variant (attention ops + the serving MHA block)
  manifest.tsv        tab-separated index the rust runtime parses:
                      kind name file batch heads seq head_dim tile_q tile_kv
                      causal order dtype num_args
  mha_weights.bin     little-endian f32 dump of the serving model weights
                      (4 square matrices, concatenated), deterministic seed.

Run via ``make artifacts`` (no-op when inputs are unchanged).
"""

from __future__ import annotations

import argparse
import os
import sys

import numpy as np
import jax
from jax._src.lib import xla_client as xc

from compile.model import (
    AttentionConfig,
    attention_example_args,
    init_mha_weights,
    jit_attention,
    jit_mha,
    mha_example_args,
)

# ---------------------------------------------------------------------------
# Variant sets.
#
# The *serving* variants must execute quickly on the CPU PJRT client, so they
# use modest sequence lengths.  They still tile exactly like the paper's
# kernels (square tiling, T=64) and include every (causal x order) cell.
# ---------------------------------------------------------------------------

SERVING_SEQS = (128, 256, 512)
SERVING_HEADS = 4
# Batch variants let the coordinator's dynamic batcher coalesce concurrent
# same-shape requests into one PJRT dispatch (padding up to the next size).
SERVING_BATCHES = (1, 4)
HEAD_DIM = 64


def serving_variants() -> list[AttentionConfig]:
    out = []
    for seq in SERVING_SEQS:
        for causal in (False, True):
            for order in ("cyclic", "sawtooth"):
                for batch in SERVING_BATCHES:
                    out.append(
                        AttentionConfig(
                            batch=batch,
                            heads=SERVING_HEADS,
                            seq=seq,
                            head_dim=HEAD_DIM,
                            causal=causal,
                            order=order,
                        )
                    )
    return out


def mha_variant() -> AttentionConfig:
    # The end-to-end serving model: 4 heads x 64 = 256 model dim, S=256.
    return AttentionConfig(
        batch=1, heads=SERVING_HEADS, seq=256, head_dim=HEAD_DIM,
        causal=True, order="sawtooth",
    )


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_attention(cfg: AttentionConfig) -> str:
    return to_hlo_text(jit_attention(cfg).lower(*attention_example_args(cfg)))


def lower_mha(cfg: AttentionConfig) -> str:
    return to_hlo_text(jit_mha(cfg).lower(*mha_example_args(cfg)))


def write_manifest_row(f, kind, name, fname, cfg: AttentionConfig, num_args: int):
    f.write(
        "\t".join(
            str(x)
            for x in (
                kind,
                name,
                fname,
                cfg.batch,
                cfg.heads,
                cfg.seq,
                cfg.head_dim,
                cfg.tile_q,
                cfg.tile_kv,
                int(cfg.causal),
                cfg.order,
                cfg.dtype,
                num_args,
            )
        )
        + "\n"
    )


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    p.add_argument(
        "--quick", action="store_true",
        help="emit only the smallest attention variant (CI smoke)",
    )
    args = p.parse_args(argv)
    os.makedirs(args.out_dir, exist_ok=True)

    variants = serving_variants()
    if args.quick:
        variants = variants[:1]

    manifest_path = os.path.join(args.out_dir, "manifest.tsv")
    with open(manifest_path, "w") as mf:
        mf.write(
            "# kind\tname\tfile\tbatch\theads\tseq\thead_dim\ttile_q\ttile_kv"
            "\tcausal\torder\tdtype\tnum_args\n"
        )
        for cfg in variants:
            fname = cfg.name + ".hlo.txt"
            text = lower_attention(cfg)
            with open(os.path.join(args.out_dir, fname), "w") as f:
                f.write(text)
            write_manifest_row(mf, "attention", cfg.name, fname, cfg, 3)
            print(f"wrote {fname} ({len(text)} chars)")

        if not args.quick:
            cfg = mha_variant()
            name = "mha_" + cfg.name
            fname = name + ".hlo.txt"
            text = lower_mha(cfg)
            with open(os.path.join(args.out_dir, fname), "w") as f:
                f.write(text)
            write_manifest_row(mf, "mha", name, fname, cfg, 5)
            print(f"wrote {fname} ({len(text)} chars)")

            # Deterministic weights for the serving model, raw little-endian
            # f32 (4 contiguous (dm, dm) matrices) — trivially parseable from
            # rust without a serialization crate.
            weights = init_mha_weights(cfg)
            buf = np.concatenate([np.asarray(w, np.float32).ravel() for w in weights])
            buf.astype("<f4").tofile(os.path.join(args.out_dir, "mha_weights.bin"))
            print(f"wrote mha_weights.bin ({buf.size} f32)")

    print(f"wrote {manifest_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
