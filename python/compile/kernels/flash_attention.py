"""Layer-1: Pallas split-Q FlashAttention with Sawtooth Wavefront Reordering.

This implements the paper's Algorithm 1 (split-Q fused multi-head attention
with square tiling) and Algorithm 4 (sawtooth KV access pattern) as a single
Pallas kernel parameterised by the KV traversal order.

Hardware adaptation (paper targets Blackwell/CUDA, we target the TPU-shaped
Pallas model — see DESIGN.md §Hardware-Adaptation):

  * "Q tile resident in shared memory" -> the Q block is pinned in VMEM
    across the KV grid dimension via its BlockSpec index map
    ``lambda i, j: (i, 0)`` (same block for every j).
  * "Load K_j, V_j into separate shared-memory buffers" -> K/V BlockSpecs
    stream one (T_kv, D) block per grid step from HBM into VMEM.
  * "WMMA tensor-core matmuls" -> ``jax.lax.dot_general`` with
    ``preferred_element_type=float32`` so S = Q K^T and O += P V lower onto
    the MXU systolic array.
  * The sawtooth reorder itself is machine independent (paper §5): here it
    is the KV BlockSpec *index transform* -- ``j`` on even Q tiles,
    ``Tc-1-j`` on odd ones -- rather than a loop-bound swap.

Pallas is always invoked with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and correctness (vs ``ref.py``) is the build
-time signal.  Real-TPU performance is estimated analytically in DESIGN.md.
"""

from __future__ import annotations

import functools
import math
from typing import Literal

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Order = Literal["cyclic", "sawtooth"]

# Large negative used to mask logits before the online-softmax max; kept
# finite so masked-everything rows produce zeros, not NaNs.
_MASK_VALUE = -1e30

__all__ = [
    "flash_attention",
    "flash_attention_batched",
    "kv_visit_order",
    "vmem_footprint_bytes",
    "mxu_utilization_estimate",
]


def kv_visit_order(q_tile_index: int, num_kv_tiles: int, order: Order) -> list[int]:
    """Python-level oracle of the KV tile visit order for one Q tile.

    Mirrors the paper's Algorithm 4: even local iterations scan forward
    (0..N_kv-1), odd ones scan backward.  Exposed so tests and the rust
    simulator can assert against one definition.
    """
    seq = list(range(num_kv_tiles))
    if order == "sawtooth" and q_tile_index % 2 == 1:
        seq.reverse()
    return seq


def _kv_block_index(i, j, num_kv_tiles: int, order: Order):
    """Traced variant of :func:`kv_visit_order` used in BlockSpec index maps."""
    if order == "cyclic":
        return j
    # Sawtooth: alternate direction with the parity of the Q-tile index.
    return jax.lax.select(i % 2 == 0, j, num_kv_tiles - 1 - j)


def _attention_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_ref,
    l_ref,
    acc_ref,
    *,
    scale: float,
    causal: bool,
    num_kv_tiles: int,
    tile_q: int,
    tile_kv: int,
    order: Order,
):
    """One (Q-tile, KV-tile) grid step of the online-softmax forward pass.

    The grid is (num_q_tiles, num_kv_tiles); the KV grid dimension is the
    paper's inner streaming loop (Algorithm 1 lines 6-12).  Accumulators
    m (running max), l (running normaliser) and acc (unnormalised output)
    live in per-Q-tile scratch blocks that persist across the KV dimension.
    """
    i = pl.program_id(0)
    j = pl.program_id(1)
    jm = _kv_block_index(i, j, num_kv_tiles, order)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _MASK_VALUE)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _step():
        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        v = v_ref[...].astype(jnp.float32)

        # S_ij = scale * Q_i K_j^T   (MXU matmul #1)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        s = s * scale

        if causal:
            # Mask the upper triangle: query row r may attend to key col c
            # iff global_r >= global_c.
            rows = i * tile_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = jm * tile_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, _MASK_VALUE)

        # Online softmax update (Algorithm 1 lines 9-10).
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = alpha * l_prev + jnp.sum(p, axis=1)

        # O_i <- alpha * O_i + P_ij V_j   (MXU matmul #2)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new
        l_ref[...] = l_new

    if causal:
        # Skip KV tiles strictly above the diagonal (fully masked).  The
        # paper's causal kernel does not *access* those tiles at all; the
        # access-count model (S(S-1)/2T) reflects that.  BlockSpec prefetch
        # still maps them, so on real hardware one would shrink the grid;
        # numerically the skip is exact.
        first_masked_row = i * tile_q + tile_q - 1  # last row of this Q tile
        needed = jm * tile_kv <= first_masked_row

        @pl.when(needed)
        def _():
            _step()
    else:
        _step()

    @pl.when(j == num_kv_tiles - 1)
    def _finalize():
        # Rows that attended to nothing (possible only with causal + padding)
        # get l == 0; emit zeros for them instead of NaN.
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_ref[...] / safe_l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("tile_q", "tile_kv", "causal", "order", "scale", "interpret"),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    tile_q: int = 64,
    tile_kv: int = 64,
    causal: bool = False,
    order: Order = "cyclic",
    scale: float | None = None,
    interpret: bool = True,
) -> jax.Array:
    """FlashAttention forward pass over single-head inputs ``(S, D)``.

    Args:
      q, k, v: arrays of shape ``(S, D)`` (same S for Q and KV, per the
        paper's square-tiling study).
      tile_q / tile_kv: block sizes; the paper's square tiling is
        ``tile_q == tile_kv`` (T=80 CUDA study, T=64 CuTile study).  S must
        be divisible by both.
      causal: apply a causal (lower-triangular) mask.
      order: ``"cyclic"`` streams KV tiles 0..Tc-1 for every Q tile;
        ``"sawtooth"`` alternates direction per Q tile (Algorithm 4).
        The result is identical up to fp reassociation.
      scale: logit scale; defaults to 1/sqrt(D).
      interpret: run the Pallas kernel in interpret mode (required on CPU).

    Returns:
      The attention output, shape ``(S, D)``, dtype of ``q``.
    """
    if q.ndim != 2 or k.ndim != 2 or v.ndim != 2:
        raise ValueError(f"expected rank-2 (S, D) inputs, got {q.shape}/{k.shape}/{v.shape}")
    seq_q, d = q.shape
    seq_kv, dk = k.shape
    if k.shape != v.shape or d != dk:
        raise ValueError(f"K/V shape mismatch: {k.shape} vs {v.shape}, D={d}")
    if seq_q % tile_q != 0:
        raise ValueError(f"S_q={seq_q} not divisible by tile_q={tile_q}")
    if seq_kv % tile_kv != 0:
        raise ValueError(f"S_kv={seq_kv} not divisible by tile_kv={tile_kv}")
    if scale is None:
        scale = 1.0 / math.sqrt(d)

    num_q_tiles = seq_q // tile_q
    num_kv_tiles = seq_kv // tile_kv

    kernel = functools.partial(
        _attention_kernel,
        scale=float(scale),
        causal=causal,
        num_kv_tiles=num_kv_tiles,
        tile_q=tile_q,
        tile_kv=tile_kv,
        order=order,
    )

    kv_index_map = lambda i, j: (_kv_block_index(i, j, num_kv_tiles, order), 0)

    return pl.pallas_call(
        kernel,
        grid=(num_q_tiles, num_kv_tiles),
        in_specs=[
            pl.BlockSpec((tile_q, d), lambda i, j: (i, 0)),  # Q resident per i
            pl.BlockSpec((tile_kv, d), kv_index_map),  # K streamed
            pl.BlockSpec((tile_kv, d), kv_index_map),  # V streamed
        ],
        out_specs=pl.BlockSpec((tile_q, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((seq_q, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tile_q,), jnp.float32),  # m: running row max
            pltpu.VMEM((tile_q,), jnp.float32),  # l: running normaliser
            pltpu.VMEM((tile_q, d), jnp.float32),  # acc: unnormalised output
        ],
        interpret=interpret,
    )(q, k, v)


def flash_attention_batched(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    **kwargs,
) -> jax.Array:
    """Batched/multi-head wrapper: inputs ``(B, H, S, D)`` (or ``(H, S, D)``).

    vmaps the single-head kernel over the leading dims, matching the paper's
    grid-y = batch*heads work distribution.
    """
    fn = functools.partial(flash_attention, **kwargs)
    for _ in range(q.ndim - 2):
        fn = jax.vmap(fn)
    return fn(q, k, v)


def vmem_footprint_bytes(
    tile_q: int, tile_kv: int, d: int, in_dtype_bytes: int = 2
) -> int:
    """Estimated VMEM bytes live per grid step (DESIGN.md §Perf, L1 layer).

    Q block + K block + V block (input dtype) + fp32 scratch (m, l, acc) +
    the fp32 logits tile the compiler materialises for S_ij.
    """
    blocks = (tile_q * d + 2 * tile_kv * d) * in_dtype_bytes
    scratch = (tile_q + tile_q + tile_q * d) * 4
    logits = tile_q * tile_kv * 4
    out = tile_q * d * in_dtype_bytes
    return blocks + scratch + logits + out


def mxu_utilization_estimate(tile_q: int, tile_kv: int, d: int, mxu: int = 128) -> float:
    """Fraction of MXU lanes occupied by the two matmuls at this tiling.

    An (m, k) x (k, n) product on an mxu x mxu systolic array is padded to
    multiples of ``mxu`` in every dimension; utilization is the ratio of
    real MACs to padded MACs, averaged over S=QK^T and O=PV weighted by
    their MAC counts.
    """

    def util(m: int, kk: int, n: int) -> float:
        pad = lambda x: mxu * math.ceil(x / mxu)
        return (m * kk * n) / (pad(m) * pad(kk) * pad(n))

    macs_s = tile_q * d * tile_kv
    macs_o = tile_q * tile_kv * d
    return (util(tile_q, d, tile_kv) * macs_s + util(tile_q, tile_kv, d) * macs_o) / (
        macs_s + macs_o
    )
