"""Pure-jnp correctness oracle for the FlashAttention Pallas kernel.

Materialises the full N x N attention matrix in float32 — exactly what
FlashAttention avoids — so it is the ground truth the fused kernel is
checked against (pytest, build time).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

__all__ = ["attention_ref", "attention_ref_batched"]


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = False,
    scale: float | None = None,
) -> jax.Array:
    """Standard scaled dot-product attention, shapes ``(S, D)``.

    Computes ``softmax(scale * Q K^T + mask) V`` in float32 and casts back
    to the input dtype.  Fully-masked rows (impossible in the square
    non-padded case, but kept for parity with the kernel) yield zeros.
    """
    seq_q, d = q.shape
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = (qf @ kf.T) * scale
    if causal:
        rows = jnp.arange(seq_q)[:, None]
        cols = jnp.arange(k.shape[0])[None, :]
        s = jnp.where(rows >= cols, s, -jnp.inf)
    m = jnp.max(s, axis=1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)  # all-masked row guard
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=1, keepdims=True)
    out = (p @ vf) / jnp.where(l == 0.0, 1.0, l)
    return out.astype(q.dtype)


def attention_ref_batched(q, k, v, **kwargs):
    """Batched oracle over leading dims, mirrors flash_attention_batched."""
    fn = functools.partial(attention_ref, **kwargs)
    for _ in range(q.ndim - 2):
        fn = jax.vmap(fn)
    return fn(q, k, v)
