"""AOT pipeline tests: HLO text validity, manifest schema, determinism."""

import os

import pytest

from compile.aot import (
    lower_attention,
    lower_mha,
    main,
    mha_variant,
    serving_variants,
)
from compile.model import AttentionConfig

SMALL = AttentionConfig(batch=1, heads=1, seq=64, head_dim=32, tile_q=32, tile_kv=32)


def test_lower_attention_is_hlo_text():
    text = lower_attention(SMALL)
    assert text.startswith("HloModule")
    # return_tuple=True: the root computation must return a tuple.
    assert "ROOT" in text and "tuple(" in text.replace(" ", "")


def test_lower_is_deterministic():
    assert lower_attention(SMALL) == lower_attention(SMALL)


def test_lower_mha_has_five_params():
    text = lower_mha(SMALL)
    assert text.startswith("HloModule")
    entry = [l for l in text.splitlines() if "ENTRY" in l][0]
    assert entry.count("parameter") == 0 or True  # params appear in body lines
    body = text[text.index("ENTRY"):]
    assert sum("parameter(" in l for l in body.splitlines()) == 5


def test_serving_variants_cover_grid():
    vs = serving_variants()
    # 3 seqs x 2 masks x 2 orders x 2 batch sizes
    assert len(vs) == 24
    names = {v.name for v in vs}
    assert len(names) == 24
    assert any(v.causal and v.order == "sawtooth" for v in vs)
    assert {v.batch for v in vs} == {1, 4}


def test_mha_variant_uses_sawtooth_causal():
    cfg = mha_variant()
    assert cfg.causal and cfg.order == "sawtooth"


def test_main_quick_writes_artifacts(tmp_path):
    out = str(tmp_path / "artifacts")
    assert main(["--out-dir", out, "--quick"]) == 0
    files = os.listdir(out)
    assert "manifest.tsv" in files
    hlo = [f for f in files if f.endswith(".hlo.txt")]
    assert len(hlo) == 1
    with open(os.path.join(out, "manifest.tsv")) as f:
        lines = [l for l in f if not l.startswith("#")]
    assert len(lines) == 1
    cols = lines[0].rstrip("\n").split("\t")
    assert len(cols) == 13
    assert cols[0] == "attention"
    assert cols[2] == hlo[0]
    assert cols[12] == "3"
