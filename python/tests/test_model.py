"""Layer-2 model tests: MHA block shapes, numerics, and lowering inputs."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile.model import (
    AttentionConfig,
    attention_example_args,
    attention_forward,
    init_mha_weights,
    jit_attention,
    jit_mha,
    mha_block_forward,
    mha_example_args,
)
from compile.kernels.ref import attention_ref_batched


CFG = AttentionConfig(batch=2, heads=2, seq=128, head_dim=32, tile_q=32, tile_kv=32)


def rand(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


def test_attention_forward_matches_ref():
    shp = (CFG.batch, CFG.heads, CFG.seq, CFG.head_dim)
    q, k, v = rand(shp, 0), rand(shp, 1), rand(shp, 2)
    out = attention_forward(CFG, q, k, v)
    ref = attention_ref_batched(q, k, v, causal=CFG.causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("order", ["cyclic", "sawtooth"])
@pytest.mark.parametrize("causal", [False, True])
def test_mha_block_output_shape_and_finite(order, causal):
    cfg = AttentionConfig(
        batch=1, heads=2, seq=64, head_dim=32, tile_q=32, tile_kv=32,
        causal=causal, order=order,
    )
    x = rand((1, 64, cfg.model_dim), 3)
    wq, wk, wv, wo = init_mha_weights(cfg)
    y = mha_block_forward(cfg, x, wq, wk, wv, wo)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))


def test_mha_block_matches_dense_reference():
    cfg = AttentionConfig(
        batch=1, heads=2, seq=64, head_dim=32, tile_q=32, tile_kv=32, causal=True
    )
    x = rand((1, 64, cfg.model_dim), 4)
    wq, wk, wv, wo = init_mha_weights(cfg)
    y = mha_block_forward(cfg, x, wq, wk, wv, wo)

    # Dense reference built from the jnp oracle.
    b, s, dm = x.shape
    h, dh = cfg.heads, cfg.head_dim
    split = lambda t: t.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    o = attention_ref_batched(split(x @ wq), split(x @ wk), split(x @ wv), causal=True)
    y_ref = x + o.transpose(0, 2, 1, 3).reshape(b, s, dm) @ wo
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=3e-4, rtol=3e-4)


def test_sawtooth_mha_equals_cyclic_mha():
    base = dict(batch=1, heads=2, seq=128, head_dim=32, tile_q=32, tile_kv=32)
    x = rand((1, 128, 64), 5)
    w = init_mha_weights(AttentionConfig(**base))
    a = mha_block_forward(AttentionConfig(**base, order="cyclic"), x, *w)
    b = mha_block_forward(AttentionConfig(**base, order="sawtooth"), x, *w)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5)


def test_example_args_shapes():
    (q, k, v) = attention_example_args(CFG)
    assert q.shape == (2, 2, 128, 32)
    (x, wq, wk, wv, wo) = mha_example_args(CFG)
    assert x.shape == (2, 128, 64) and wq.shape == (64, 64)


def test_config_name_roundtrip_fields():
    cfg = AttentionConfig(batch=1, heads=4, seq=256, head_dim=64, causal=True, order="sawtooth")
    assert cfg.name == "attn_b1_h4_s256_d64_causal_sawtooth"
    assert cfg.model_dim == 256


def test_jitted_functions_lower():
    cfg = AttentionConfig(batch=1, heads=1, seq=64, head_dim=32, tile_q=32, tile_kv=32)
    lowered = jit_attention(cfg).lower(*attention_example_args(cfg))
    assert "stablehlo" in str(lowered.compiler_ir("stablehlo"))
    lowered = jit_mha(cfg).lower(*mha_example_args(cfg))
    assert lowered is not None


def test_init_weights_deterministic():
    a = init_mha_weights(CFG, seed=7)
    b = init_mha_weights(CFG, seed=7)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
