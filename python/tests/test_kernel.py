"""Kernel-vs-oracle correctness: the core L1 signal.

Covers: both traversal orders, causal/non-causal, rectangular tiles,
non-square S_q != S_kv, dtypes (f32/bf16), numeric-range robustness, the
visit-order oracle, and hypothesis sweeps over shapes/tiles/dtypes.
"""

import math

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.flash_attention import (
    flash_attention,
    flash_attention_batched,
    kv_visit_order,
    mxu_utilization_estimate,
    vmem_footprint_bytes,
)
from compile.kernels.ref import attention_ref, attention_ref_batched


def rand(shape, seed=0, dtype=jnp.float32, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)


def assert_matches_ref(q, k, v, tol=2e-5, **kw):
    out = flash_attention(q, k, v, **kw)
    ref = attention_ref(q, k, v, causal=kw.get("causal", False), scale=kw.get("scale"))
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


# ---------------------------------------------------------------------------
# Basic grid of configurations.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("order", ["cyclic", "sawtooth"])
@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("seq,tile", [(128, 32), (128, 64), (256, 64), (320, 80)])
def test_matches_reference(order, causal, seq, tile):
    q = rand((seq, 64), 1)
    k = rand((seq, 64), 2)
    v = rand((seq, 64), 3)
    assert_matches_ref(q, k, v, tile_q=tile, tile_kv=tile, causal=causal, order=order)


@pytest.mark.parametrize("order", ["cyclic", "sawtooth"])
def test_rectangular_tiles(order):
    q, k, v = rand((128, 32), 4), rand((128, 32), 5), rand((128, 32), 6)
    assert_matches_ref(q, k, v, tile_q=32, tile_kv=64, order=order)
    assert_matches_ref(q, k, v, tile_q=64, tile_kv=32, order=order)


@pytest.mark.parametrize("order", ["cyclic", "sawtooth"])
@pytest.mark.parametrize("causal", [False, True])
def test_cross_attention_lengths(order, causal):
    # S_q != S_kv (decode-like); causal masks relative to absolute indices.
    q = rand((64, 64), 7)
    k = rand((256, 64), 8)
    v = rand((256, 64), 9)
    assert_matches_ref(q, k, v, tile_q=32, tile_kv=64, causal=causal, order=order)


def test_single_tile():
    q, k, v = rand((64, 64), 10), rand((64, 64), 11), rand((64, 64), 12)
    assert_matches_ref(q, k, v, tile_q=64, tile_kv=64)
    assert_matches_ref(q, k, v, tile_q=64, tile_kv=64, order="sawtooth")


def test_custom_scale():
    q, k, v = rand((128, 64), 13), rand((128, 64), 14), rand((128, 64), 15)
    assert_matches_ref(q, k, v, tile_q=64, tile_kv=64, scale=0.25)


def test_bfloat16():
    q = rand((128, 64), 16, jnp.bfloat16)
    k = rand((128, 64), 17, jnp.bfloat16)
    v = rand((128, 64), 18, jnp.bfloat16)
    out = flash_attention(q, k, v, tile_q=64, tile_kv=64)
    ref = attention_ref(q, k, v)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=3e-2, rtol=3e-2
    )


def test_large_magnitude_logits_stable():
    # Online softmax must not overflow even with logits ~ +-60.
    q = rand((128, 64), 19, scale=8.0)
    k = rand((128, 64), 20, scale=8.0)
    v = rand((128, 64), 21)
    out = flash_attention(q, k, v, tile_q=64, tile_kv=64)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert_matches_ref(q, k, v, tol=5e-4, tile_q=64, tile_kv=64)


def test_identical_keys_uniform_attention():
    # All keys identical -> attention is the mean of V rows.
    k = jnp.ones((128, 64), jnp.float32)
    q = rand((128, 64), 22)
    v = rand((128, 64), 23)
    out = flash_attention(q, k, v, tile_q=64, tile_kv=64)
    np.testing.assert_allclose(
        np.asarray(out), np.tile(np.asarray(v).mean(0), (128, 1)), atol=1e-5
    )


# ---------------------------------------------------------------------------
# Sawtooth-specific invariants.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("causal", [False, True])
def test_sawtooth_equals_cyclic(causal):
    """The reorder only reassociates fp addition: results stay ~identical."""
    q, k, v = rand((512, 64), 24), rand((512, 64), 25), rand((512, 64), 26)
    a = flash_attention(q, k, v, tile_q=64, tile_kv=64, causal=causal, order="cyclic")
    b = flash_attention(q, k, v, tile_q=64, tile_kv=64, causal=causal, order="sawtooth")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5)


def test_kv_visit_order_definition():
    assert kv_visit_order(0, 4, "cyclic") == [0, 1, 2, 3]
    assert kv_visit_order(1, 4, "cyclic") == [0, 1, 2, 3]
    assert kv_visit_order(0, 4, "sawtooth") == [0, 1, 2, 3]
    assert kv_visit_order(1, 4, "sawtooth") == [3, 2, 1, 0]
    assert kv_visit_order(2, 4, "sawtooth") == [0, 1, 2, 3]


def test_kv_visit_order_is_permutation():
    for i in range(5):
        for n in (1, 3, 8):
            for order in ("cyclic", "sawtooth"):
                assert sorted(kv_visit_order(i, n, order)) == list(range(n))


# ---------------------------------------------------------------------------
# Batched wrapper.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("order", ["cyclic", "sawtooth"])
def test_batched_matches_ref(order):
    q = rand((2, 3, 128, 64), 27)
    k = rand((2, 3, 128, 64), 28)
    v = rand((2, 3, 128, 64), 29)
    out = flash_attention_batched(q, k, v, tile_q=64, tile_kv=64, order=order)
    ref = attention_ref_batched(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_batched_matches_per_head_loop():
    q, k, v = rand((1, 2, 128, 64), 30), rand((1, 2, 128, 64), 31), rand((1, 2, 128, 64), 32)
    out = flash_attention_batched(q, k, v, tile_q=64, tile_kv=64)
    for h in range(2):
        single = flash_attention(q[0, h], k[0, h], v[0, h], tile_q=64, tile_kv=64)
        np.testing.assert_allclose(np.asarray(out[0, h]), np.asarray(single), atol=1e-6)


# ---------------------------------------------------------------------------
# Input validation.
# ---------------------------------------------------------------------------


def test_rejects_indivisible_seq():
    q, k, v = rand((100, 64)), rand((100, 64)), rand((100, 64))
    with pytest.raises(ValueError, match="not divisible"):
        flash_attention(q, k, v, tile_q=64, tile_kv=64)


def test_rejects_rank_mismatch():
    with pytest.raises(ValueError, match="rank-2"):
        flash_attention(rand((2, 64, 64)), rand((64, 64)), rand((64, 64)))


def test_rejects_kv_shape_mismatch():
    with pytest.raises(ValueError, match="mismatch"):
        flash_attention(rand((64, 64)), rand((64, 64)), rand((128, 64)))


# ---------------------------------------------------------------------------
# Hypothesis sweeps: shapes / tiles / dtype / order / mask.
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    tiles_q=st.integers(1, 4),
    tiles_kv=st.integers(1, 4),
    tile=st.sampled_from([16, 32, 48]),
    d=st.sampled_from([16, 32, 64]),
    causal=st.booleans(),
    order=st.sampled_from(["cyclic", "sawtooth"]),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_matches_ref(tiles_q, tiles_kv, tile, d, causal, order, seed):
    sq, skv = tiles_q * tile, tiles_kv * tile
    q = rand((sq, d), seed)
    k = rand((skv, d), seed + 1)
    v = rand((skv, d), seed + 2)
    out = flash_attention(q, k, v, tile_q=tile, tile_kv=tile, causal=causal, order=order)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)


@settings(max_examples=15, deadline=None)
@given(
    dtype=st.sampled_from(["float32", "bfloat16"]),
    tile=st.sampled_from([32, 64]),
    ntiles=st.integers(1, 3),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_dtypes(dtype, tile, ntiles, seed):
    s = tile * ntiles
    dt = jnp.dtype(dtype)
    q, k, v = rand((s, 32), seed, dt), rand((s, 32), seed + 1, dt), rand((s, 32), seed + 2, dt)
    out = flash_attention(q, k, v, tile_q=tile, tile_kv=tile, order="sawtooth")
    ref = attention_ref(q, k, v)
    tol = 3e-5 if dtype == "float32" else 4e-2
    assert out.dtype == dt
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


# ---------------------------------------------------------------------------
# Perf-estimate helpers (used by DESIGN.md §Perf): sanity only.
# ---------------------------------------------------------------------------


def test_vmem_footprint_monotone_and_fits():
    f64 = vmem_footprint_bytes(64, 64, 64)
    f128 = vmem_footprint_bytes(128, 128, 64)
    assert f64 < f128
    # The production tiling must fit a 16 MiB VMEM with generous headroom.
    assert f128 < 4 * 1024 * 1024


def test_mxu_utilization_bounds():
    for t in (16, 32, 64, 80, 128):
        u = mxu_utilization_estimate(t, t, 64)
        assert 0.0 < u <= 1.0
    # 128-aligned tiling saturates the array.
    assert mxu_utilization_estimate(128, 128, 128) == 1.0
