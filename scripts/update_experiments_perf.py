#!/usr/bin/env python3
"""Fill EXPERIMENTS.md's measured-numbers block from the bench JSON files.

Reads rust/BENCH_sweep.json, rust/BENCH_reuse.json, rust/BENCH_policy.json,
rust/BENCH_serve.json, rust/BENCH_decode.json, rust/BENCH_hierarchy.json and
rust/BENCH_shard.json (produced by `make bench-perf`, or downloaded from the
CI artifacts) and rewrites the region between the `<!-- BENCH:begin -->` /
`<!-- BENCH:end -->` markers in EXPERIMENTS.md.

Missing or partial bench files are skipped with a warning on stderr instead
of failing the whole fold — a host that only ran some of the benches (or a
CI run whose artifact set is incomplete) still gets every section it has
numbers for.

Usage: python3 scripts/update_experiments_perf.py   (from the repo root,
or anywhere — paths are resolved relative to this file).
"""

import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
EXPERIMENTS = ROOT / "EXPERIMENTS.md"
BEGIN = "<!-- BENCH:begin -->"
END = "<!-- BENCH:end -->"

BENCH_FILES = (
    "BENCH_sweep.json",
    "BENCH_reuse.json",
    "BENCH_policy.json",
    "BENCH_serve.json",
    "BENCH_decode.json",
    "BENCH_hierarchy.json",
    "BENCH_shard.json",
)


def warn(msg):
    print(f"warning: {msg}", file=sys.stderr)


def load(name):
    path = ROOT / "rust" / name
    if not path.exists():
        warn(f"{name} not found — skipping its section")
        return None
    try:
        with open(path) as f:
            return json.load(f)
    except ValueError as e:
        warn(f"{name} is not valid JSON ({e}) — skipping its section")
        return None


def render_sweep(sweep):
    lines = []
    lines.append("Sweep executor (`bench_sweep`, %d configs, %d threads):" % (sweep["configs"], sweep["threads"]))
    lines.append("")
    lines.append("| path | wall-clock |")
    lines.append("|---|---|")
    lines.append("| sequential | %.3f s |" % sweep["sequential_s"])
    lines.append(
        "| parallel ×%d | %.3f s (**%.2fx**) |" % (sweep["threads"], sweep["parallel_s"], sweep["speedup"])
    )
    lines.append("| memoized re-run | %.6f s |" % sweep["memoized_rerun_s"])
    lines.append("")
    return lines


def render_reuse(reuse):
    lines = []
    lines.append(
        "Reuse-distance fast path (`bench_reuse`, %d configs = %d capacities × 2 orders):"
        % (reuse["configs"], reuse["capacities"])
    )
    lines.append("")
    lines.append("| path | wall-clock |")
    lines.append("|---|---|")
    lines.append("| per-capacity simulation (`--no-mattson`) | %.3f s |" % reuse["ungrouped_s"])
    lines.append("| grouped Mattson profile | %.3f s (**%.2fx**) |" % (reuse["grouped_s"], reuse["speedup"]))
    lines.append("| 64 what-if capacities from cached curve | %.6f s |" % reuse["whatif_64caps_s"])
    lines.append("")
    lines.append("Results bit-identical across paths: `%s`." % reuse["results_identical"])
    lines.append("")
    if "cutile_fast_s" in reuse:
        lines.append(
            "Front-stack fast path (§4.3 CuTile study shape, S=128K B=8, "
            "Mattson profile):"
        )
        lines.append("")
        lines.append("| path | wall-clock |")
        lines.append("|---|---|")
        lines.append("| front stack off (Fenwick per access) | %.3f s |" % reuse["cutile_slow_s"])
        lines.append(
            "| front stack on (default) | %.3f s (**%.2fx**) |"
            % (reuse["cutile_fast_s"], reuse["cutile_speedup"])
        )
        lines.append("")
        lines.append(
            "Fast-path engagement: %.1f%% (CuTile S=128K), %.1f%% (CUDA "
            "S=64K); curves bit-identical: `%s`."
            % (
                100.0 * reuse["cutile_engagement"],
                100.0 * reuse["cuda_engagement"],
                reuse["cutile_curves_identical"],
            )
        )
        lines.append("")
    return lines


def render_policy(policy):
    lines = []
    lines.append(
        "Policy engine (`bench_policy`, %d candidates, winner `%s`):"
        % (policy["candidates"], policy["winner"])
    )
    lines.append("")
    lines.append("| path | wall-clock |")
    lines.append("|---|---|")
    lines.append("| cold decide, 1 probe thread | %.3f s |" % policy["cold_decide_1t_s"])
    lines.append(
        "| cold decide, %d probe threads | %.3f s (**%.2fx**) |"
        % (policy["threads"], policy["cold_decide_nt_s"], policy["fanout_speedup"])
    )
    lines.append("| cached decide (per call) | %.9f s |" % policy["cached_decide_s"])
    lines.append(
        "| %d per-capacity what-ifs from cached curves | %.6f s |"
        % (policy["whatif_caps"], policy["whatif_s"])
    )
    return lines


def render_serve(serve):
    lines = []
    lines.append(
        "Serving engine (`bench_coordinator`, %d requests, %d clients, "
        "mixed 128/256/512 Poisson load; static windows vs continuous "
        "batching):" % (serve["requests"], serve["clients"])
    )
    lines.append("")
    lines.append(
        "| offered load | mode | throughput | in-queue mean | in-queue p99 "
        "| shed | tokens/batch |"
    )
    lines.append("|---|---|---|---|---|---|---|")
    for pt in serve["points"]:
        for mode in ("static", "continuous"):
            m = pt[mode]
            lines.append(
                "| %.0f req/s | %s | %.1f req/s | %.2f ms | %.2f ms "
                "| %.1f%% | %.0f |"
                % (
                    pt["offered_rps"],
                    mode,
                    m["throughput_rps"],
                    m["tiq_mean_ms"],
                    m["tiq_p99_ms"],
                    100.0 * m["shed_rate"],
                    m["mean_tokens_per_batch"],
                )
            )
    return lines


def render_decode(decode):
    lines = []
    lines.append(
        "Decode shapes (`bench_decode`, %s; L2 miss sectors, weighted "
        "model):" % decode["grid"]
    )
    lines.append("")
    lines.append("| shape | cyclic | sawtooth | best (registry) |")
    lines.append("|---|---|---|---|")
    lines.append(
        "| prefill q=32K | %d | %d | `%s` (%d) |"
        % (
            decode["prefill_cyclic_misses"],
            decode["prefill_sawtooth_misses"],
            decode["prefill_best_order"],
            decode["prefill_best_misses"],
        )
    )
    lines.append(
        "| decode q=1 | %d | %d | `%s` (%d) |"
        % (
            decode["decode_cyclic_misses"],
            decode["decode_sawtooth_misses"],
            decode["decode_best_order"],
            decode["decode_best_misses"],
        )
    )
    lines.append("")
    lines.append(
        "MQA (kv_heads 8→1) decode misses: %d (%.2fx fewer than "
        "ungrouped); exact-LRU paged ≡ contiguous: `%s`."
        % (
            decode["mqa_decode_misses"],
            decode["gqa_miss_ratio"],
            decode["exact_paged_identical"],
        )
    )
    return lines


def render_hierarchy(hierarchy):
    lines = []
    lines.append(
        "Hierarchy level (`bench_hierarchy`, %s; L2-from-tex sectors "
        "with the per-SM L1/MSHR model on vs off):" % hierarchy["grid"]
    )
    lines.append("")
    lines.append(
        "| order | L2 from tex (off) | L2 from tex (on) | L1 filtered "
        "| sector hit % | MSHR merges | sim overhead |"
    )
    lines.append("|---|---|---|---|---|---|---|")
    for order in ("cyclic", "sawtooth"):
        if f"{order}_off_l2_from_tex" not in hierarchy:
            continue
        lines.append(
            "| %s | %d | %d | %.1f%% | %.1f%% | %d | %.2fx |"
            % (
                order,
                hierarchy[f"{order}_off_l2_from_tex"],
                hierarchy[f"{order}_on_l2_from_tex"],
                100.0 * hierarchy[f"{order}_l1_filter_rate"],
                hierarchy[f"{order}_l1_sector_hit_pct"],
                hierarchy[f"{order}_mshr_merges"],
                hierarchy[f"{order}_sim_overhead"],
            )
        )
    return lines


def render_shard(shard):
    lines = []
    lines.append(
        "Shard planner (`bench_shard`, %s; end-to-end = straggler chip + "
        "collective):" % shard["grid"]
    )
    lines.append("")
    lines.append("| shards | axis | straggler misses | collective MiB | time | vs 1 chip |")
    lines.append("|---|---|---|---|---|---|")
    lines.append(
        "| 1 | - | %d | 0 | %.3f ms | 1.00x |"
        % (shard["unsharded_misses"], 1e3 * shard["unsharded_time_s"])
    )
    for axis in ("head", "seq"):
        for n in (2, 4, 8):
            if f"{axis}_{n}_time_s" not in shard:
                continue
            lines.append(
                "| %d | %s | %d | %.1f | %.3f ms | %.2fx |"
                % (
                    n,
                    axis,
                    shard[f"{axis}_{n}_straggler_misses"],
                    shard[f"{axis}_{n}_collective_bytes"] / (1024.0 * 1024.0),
                    1e3 * shard[f"{axis}_{n}_time_s"],
                    shard[f"{axis}_{n}_speedup"],
                )
            )
    lines.append("")
    lines.append(
        "Axis flip (4-way MQA over cx7): short KV winner `%s`, long KV "
        "winner `%s` — asserted inline by the bench."
        % (shard["flip_short_kv_winner"], shard["flip_long_kv_winner"])
    )
    return lines


SECTIONS = (
    ("BENCH_sweep.json", render_sweep),
    ("BENCH_reuse.json", render_reuse),
    ("BENCH_policy.json", render_policy),
    ("BENCH_serve.json", render_serve),
    ("BENCH_decode.json", render_decode),
    ("BENCH_hierarchy.json", render_hierarchy),
    ("BENCH_shard.json", render_shard),
)


def render():
    sections = []
    for name, fn in SECTIONS:
        data = load(name)
        if data is None:
            continue
        try:
            sections.append(fn(data))
        except KeyError as e:
            warn(f"{name} is missing key {e} (partial bench run?) — skipping its section")
    if not sections:
        return [
            "*No measured numbers yet: run `make bench-perf` on a ≥8-core "
            "host (or download the CI `BENCH_sweep`/`BENCH_reuse`/"
            "`BENCH_policy`/`BENCH_serve`/`BENCH_decode`/`BENCH_hierarchy`/"
            "`BENCH_shard` artifacts into `rust/`) and re-run "
            "`python3 scripts/update_experiments_perf.py`.*"
        ]
    lines = []
    for i, section in enumerate(sections):
        if i > 0 and lines and lines[-1] != "":
            lines.append("")
        lines.extend(section)
    # Normalize: no trailing blank line inside the block.
    while lines and lines[-1] == "":
        lines.pop()
    return lines


def main():
    text = EXPERIMENTS.read_text()
    if BEGIN not in text or END not in text:
        sys.exit(f"markers {BEGIN} / {END} not found in {EXPERIMENTS}")
    head, rest = text.split(BEGIN, 1)
    _, tail = rest.split(END, 1)
    block = "\n".join(render())
    EXPERIMENTS.write_text(head + BEGIN + "\n" + block + "\n" + END + tail)
    print(f"updated {EXPERIMENTS}")


if __name__ == "__main__":
    main()
